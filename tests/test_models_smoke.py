"""Per-arch smoke tests (deliverable (f)): reduced config, one train step +
decode steps on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import batch_for
from repro.models import build_model
from repro.models.lm import param_count

SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_forward_loss_and_grad(arch_id, key):
    cfg = ARCHS[arch_id].reduced()
    bundle = build_model(cfg)
    params = bundle.init(key)
    batch = batch_for(cfg, SHAPE)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_decode_steps(arch_id, key):
    cfg = ARCHS[arch_id].reduced()
    bundle = build_model(cfg)
    params = bundle.init(key)
    batch = batch_for(cfg, SHAPE)
    state = bundle.decode_init(params, batch, 32)
    step = jax.jit(bundle.decode_step)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, state = step(params, state, tok)
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_full_config_abstract_shapes(arch_id):
    """FULL configs are exercised abstractly (no allocation) — the param
    tree must build and match the published architecture dimensions."""
    from repro.models import abstract_params

    cfg = ARCHS[arch_id]
    params = abstract_params(cfg)
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    expected = {
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "phi4-mini-3.8b": (3.0e9, 4.8e9),
        "llava-next-mistral-7b": (6.5e9, 8.0e9),
        "nemotron-4-340b": (300e9, 380e9),
        "mixtral-8x22b": (130e9, 150e9),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "whisper-large-v3": (1.3e9, 2.2e9),
    }[arch_id]
    assert expected[0] <= n <= expected[1], f"{arch_id}: {n / 1e9:.2f}B params"


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg = ARCHS["smollm-360m"].reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(1))
    toks = jax.random.randint(jax.random.key(2), (2, 12), 0, cfg.vocab)
    from repro.models.lm import forward

    full = forward(params, cfg, {"tokens": toks})  # (2, 12, V)
    state = bundle.decode_init(params, {"tokens": toks}, 16)
    outs = []
    for t in range(12):
        logits, state = bundle.decode_step(params, state, toks[:, t : t + 1])
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3)


def test_decode_matches_forward_sliding_window():
    """Rolling-cache decode == full forward with SWA (mixtral-style).

    capacity_factor is raised so no token is dropped: decode (S=1) never
    drops, so parity only holds in the no-drop regime — with the default
    1.25 the forward pass legitimately drops late tokens at tiny S.
    """
    import dataclasses

    base = ARCHS["mixtral-8x22b"].reduced()
    cfg = dataclasses.replace(
        base,
        sliding_window=8,
        moe=dataclasses.replace(base.moe, capacity_factor=8.0),
    )
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(3))
    toks = jax.random.randint(jax.random.key(4), (1, 20), 0, cfg.vocab)
    from repro.models.lm import forward

    full = forward(params, cfg, {"tokens": toks})
    state = bundle.decode_init(params, {"tokens": toks}, 64)
    outs = []
    for t in range(20):
        logits, state = bundle.decode_step(params, state, toks[:, t : t + 1])
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3)


def test_vlm_patch_projection_changes_logits():
    cfg = ARCHS["llava-next-mistral-7b"].reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(5))
    batch = batch_for(cfg, SHAPE)
    l1 = bundle.loss(params, batch)
    batch2 = dict(batch, patches=batch["patches"] + 1.0)
    l2 = bundle.loss(params, batch2)
    assert float(jnp.abs(l1 - l2)) > 1e-6


def test_zamba2_shared_block_is_tied():
    cfg = ARCHS["zamba2-1.2b"].reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(6))
    # exactly one shared attention block regardless of depth
    assert "shared" in params
    n_shared = param_count(params["shared"])
    assert n_shared > 0
