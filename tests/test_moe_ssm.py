"""MoE dispatch + Mamba2 SSD unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.models.moe import (
    aux_load_balance_loss,
    init_moe,
    moe_ffn_dense,
    moe_ffn_sparse,
)
from repro.models.ssm import (
    init_mamba,
    mamba_block,
    mamba_decode_step,
    ssd_chunked,
    ssd_naive,
)


def _moe_setup(e=4, k=2, cf=8.0):
    moe = MoEConfig(n_experts=e, top_k=k, d_ff=32, capacity_factor=cf)
    cfg = ArchConfig(
        arch_id="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab=64, moe=moe, param_dtype="float32",
    )
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    return moe, params


def test_sparse_equals_dense_without_drops():
    moe, p = _moe_setup()
    x = jax.random.normal(jax.random.key(1), (3, 16, 16))
    np.testing.assert_allclose(
        np.asarray(moe_ffn_sparse(p, x, moe)),
        np.asarray(moe_ffn_dense(p, x, moe)),
        atol=1e-5,
    )


def test_sparse_tight_capacity_drops_but_finite():
    moe, p = _moe_setup(cf=0.3)
    x = jax.random.normal(jax.random.key(2), (2, 32, 16))
    ys = moe_ffn_sparse(p, x, moe)
    yd = moe_ffn_dense(p, x, moe)
    assert bool(jnp.all(jnp.isfinite(ys)))
    # some tokens must differ (dropped contributions)
    assert float(jnp.max(jnp.abs(ys - yd))) > 1e-4


def test_topk_weights_sum_to_one():
    from repro.models.moe import _router_topk

    moe, p = _moe_setup(e=8, k=3)
    x2 = jax.random.normal(jax.random.key(3), (64, 16))
    w, idx = _router_topk(p, x2, moe)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < 8


def test_aux_loss_uniform_vs_collapsed():
    moe, p = _moe_setup(e=4, k=1)
    x = jax.random.normal(jax.random.key(4), (2, 64, 16))
    base = float(aux_load_balance_loss(p, x, moe))
    # collapse the router onto expert 0
    p2 = dict(p, router=p["router"].at[:, 0].set(100.0))
    collapsed = float(aux_load_balance_loss(p2, x, moe))
    assert collapsed > base


def test_moe_grads_flow_through_sparse_dispatch():
    moe, p = _moe_setup()
    x = jax.random.normal(jax.random.key(5), (2, 8, 16))
    g = jax.grad(lambda pp: jnp.sum(moe_ffn_sparse(pp, x, moe) ** 2))(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).max()) > 0, f"no grad into {name}"


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_naive(chunk):
    L, H, P_, N = 64, 2, 8, 16
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (L, H, P_))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (L, H)) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (L, N)) * 0.5
    C = jax.random.normal(ks[4], (L, N)) * 0.5
    D = jnp.ones((H,))
    got = ssd_chunked(x, dt, A, B, C, D, chunk)
    want = ssd_naive(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_ssd_gradient_finite():
    L, H, P_, N = 32, 2, 8, 8
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (L, H, P_))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (L, N))
    C = jax.random.normal(ks[4], (L, N))
    D = jnp.ones((H,))
    g = jax.grad(lambda x: jnp.sum(ssd_chunked(x, dt, A, B, C, D, 16) ** 2))(x)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_mamba_decode_continues_block():
    cfg = ArchConfig(
        arch_id="t", family="ssm", n_layers=1, d_model=32, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab=64,
        ssm=SSMConfig(d_state=16, head_dim=16, chunk=16),
        param_dtype="float32", compute_dtype="float32",
    )
    p = init_mamba(jax.random.key(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 21, 32)) * 0.5
    full = mamba_block(p, x, cfg)
    ssm = cfg.ssm
    h = jnp.zeros((2, ssm.n_heads(32), ssm.head_dim, ssm.d_state))
    conv = jnp.zeros((2, ssm.d_conv - 1, ssm.d_inner(32) + 2 * ssm.d_state))
    ys = []
    for t in range(21):
        y, h, conv = mamba_decode_step(p, x[:, t : t + 1], h, conv, cfg)
        ys.append(y)
    dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)
