"""Paged KV-cache serving tests (DESIGN.md §10).

Fast layers (fake chunk/step functions, no device work) cover the
PagedDecodePool lifecycle: block-granular admission with head-of-line
FIFO backpressure, chunked-prefill fairness on a fake clock, block-lease
accounting across EOS/length eviction and pool death, and the typed
never-fits rejection.  Real-model tests then pin the numerical contract
of the whole PR — paged block-table decode, chunked prefill through the
pool and speculative decoding all emit tokens bit-identical to the
request-per-generation baseline — on a dense and an SSM family.
"""
import numpy as np
import pytest

from repro.balancer import (
    LoadBalancer,
    PagedDecodePool,
    PromptTooLongError,
)
from repro.configs import ARCHS
from repro.runtime.serve_loop import ServingEngine

REAL_ARCHS = ["qwen2-0.5b", "mamba2-1.3b"]


# ---------------------------------------------------------------------------
# Fake-pool fixtures: block accounting without device work
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def fake_paged_pool(
    n_slots=4,
    *,
    n_blocks=3,
    block_size=4,
    max_blocks_per_slot=2,
    max_positions=8,
    prefill_chunk=2,
    clock=None,
    **kw,
):
    """A PagedDecodePool whose 'model' emits last-input+1 each call.

    ``chunk_fn`` returns ``chunk[-1] + 1`` (the would-be first token),
    ``step_fn`` returns ``tokens + 1`` — so a prompt ``[10, 11]`` streams
    ``[12, 13, 14, ...]`` and every emission is predictable.
    """

    def step_fn(state, toks, active):
        return state + 1, np.asarray(toks) + 1

    def chunk_fn(state, slot, chunk, start_pos):
        return state + 1, int(chunk[-1]) + 1

    def reset_fn(state, slot, row):
        return state

    return PagedDecodePool(
        step_fn,
        chunk_fn,
        reset_fn,
        lambda: 0,
        n_slots,
        n_blocks=n_blocks,
        block_size=block_size,
        max_blocks_per_slot=max_blocks_per_slot,
        max_positions=max_positions,
        prefill_chunk=prefill_chunk,
        clock=clock or FakeClock(),
        **kw,
    )


def theta(prompt, n_new, eos=None):
    return (np.asarray(prompt, dtype=np.int64).reshape(1, -1), n_new, eos)


def test_chunked_prefill_token_stream_and_ttft_boundary():
    """Prefill runs through the pool in chunks; the first token is emitted
    at the boundary the prompt completes and the fused step of that SAME
    boundary appends the second."""
    clock = FakeClock()
    pool = fake_paged_pool(n_slots=1, clock=clock)
    lb = LoadBalancer([pool])
    # prompt len 3, chunk 2 -> boundaries: [10,11] then [12] -> tok 13
    r = lb.submit_async(theta([10, 11, 12], 4), tag="")
    res = lb.result(r, timeout=5)
    assert res.tokens.tolist() == [13, 14, 15, 16]
    # 13 (prefill completion) and 14 (fused step) share boundary 2: their
    # clock stamps are adjacent ticks, strictly after the empty boundary 1.
    assert res.token_times == sorted(res.token_times)
    assert len(res.token_times) == 4
    assert pool.block_usage() == (0, pool.n_blocks)
    lb.shutdown()


def test_block_backpressure_preserves_fifo_head_of_line():
    """When the queue head does not fit in free blocks, later requests
    that WOULD fit must wait behind it (no head-of-line skipping)."""
    pool = fake_paged_pool(n_slots=4, n_blocks=3, block_size=4)
    lb = LoadBalancer([pool])
    # A: 2+2-1 = 3 positions -> 1 block, finishes at the first boundary.
    # B: 2+5-1 = 6 positions -> 2 blocks, runs 4 boundaries longer.
    # C: 2 blocks — must wait for BOTH of B's blocks even though A's
    #    single freed block would admit D at an earlier boundary.
    # D: 1 block — fits the moment A evicts, but C holds the queue head.
    ra = lb.submit_async(theta([1, 2], 2), tag="")
    rb = lb.submit_async(theta([1, 2], 5), tag="")
    rc = lb.submit_async(theta([1, 2], 5), tag="")
    rd = lb.submit_async(theta([1, 2], 2), tag="")
    for r in (ra, rb, rc, rd):
        lb.result(r, timeout=5)
    admitted = [req for _, req in pool.admit_log]
    assert admitted == [ra, rb, rc, rd], "block backpressure broke FIFO"
    # every lease was returned
    assert pool.block_usage() == (0, 3)
    assert pool.n_free == pool.n_slots
    lb.shutdown()


def test_chunked_prefill_fifo_fairness_on_fake_clock():
    """With one slot, the second request's entire generation — including
    its chunked prefill — happens strictly after the first completes."""
    clock = FakeClock()
    pool = fake_paged_pool(n_slots=1, clock=clock, max_positions=8)
    lb = LoadBalancer([pool])
    ra = lb.submit_async(theta([1, 2, 3, 4], 2), tag="")
    rb = lb.submit_async(theta([5, 6, 7, 8], 2), tag="")
    res_a = lb.result(ra, timeout=5)
    res_b = lb.result(rb, timeout=5)
    assert res_a.tokens.tolist() == [5, 6]
    assert res_b.tokens.tolist() == [9, 10]
    assert res_b.token_times[0] > res_a.token_times[-1]
    lb.shutdown()


def test_no_block_leak_on_eos_length_eviction_and_death():
    pool = fake_paged_pool(n_slots=4, n_blocks=3, block_size=4)
    lb = LoadBalancer([pool])
    # EOS eviction: prompt [5,6] -> stream 7, 8; eos=8 stops budget 6 early.
    r_eos = lb.submit_async(theta([5, 6], 6, eos=8), tag="")
    # Max-length eviction.
    r_len = lb.submit_async(theta([1, 2], 3), tag="")
    assert lb.result(r_eos, timeout=5).tokens.tolist() == [7, 8]
    assert lb.result(r_len, timeout=5).tokens.tolist() == [3, 4, 5]
    assert pool.block_usage() == (0, 3)
    assert sorted(pool._free_blocks) == [1, 2, 3]
    assert pool.n_free == pool.n_slots
    lb.shutdown()

    # Pool death mid-flight: clear() must return every leased block too.
    pool2 = fake_paged_pool(n_slots=2, n_blocks=3, block_size=4)
    pool2.admit(_FakeReq(theta([1, 2], 5)), now=0.0)
    pool2.admit(_FakeReq(theta([1, 2], 2)), now=0.0)
    assert pool2.block_usage() == (3, 3)
    pool2.clear()
    assert pool2.block_usage() == (0, 3)
    assert pool2.n_free == pool2.n_slots


class _FakeReq:
    """Just enough of a Request for direct pool.admit() calls."""

    def __init__(self, th):
        self.theta = th
        self.tag = ""


def test_never_fits_raises_typed_error_and_pool_survives():
    pool = fake_paged_pool(n_slots=2, n_blocks=3, block_size=4, max_positions=8)
    # Direct admission: too many positions -> typed error, no lease taken.
    with pytest.raises(PromptTooLongError):
        pool.admit(_FakeReq(theta([1] * 6, 4)), now=0.0)  # 9 positions > 8
    with pytest.raises(PromptTooLongError):
        pool.admit(_FakeReq(theta([], 4)), now=0.0)  # empty prompt
    assert pool.block_usage() == (0, 3)
    assert pool.n_free == pool.n_slots
    # ...and a never-fits request reports admissible so the dispatcher
    # pops it for the typed rejection instead of parking at the head.
    assert pool.admissible(theta([1] * 6, 4))

    # Through the balancer: the request fails, the pool keeps serving.
    lb = LoadBalancer([pool])
    r_bad = lb.submit_async(theta([1] * 6, 4), tag="")
    r_ok = lb.submit_async(theta([1, 2], 2), tag="")
    with pytest.raises(PromptTooLongError):
        lb.result(r_bad, timeout=5)
    assert lb.result(r_ok, timeout=5).tokens.tolist() == [3, 4]
    assert lb.telemetry.fault_count("rejected") == 1
    lb.shutdown()


# ---------------------------------------------------------------------------
# Real models: the bit-identity contract of the whole PR
# ---------------------------------------------------------------------------
def _run_workload(variants, mode, work, **engine_kw):
    with ServingEngine(variants, mode=mode, cache_len=24, **engine_kw) as eng:
        gens = [eng.submit(v, p, n) for v, p, n in work]
        tokens = [g.result(timeout=300).tokens.tolist() for g in gens]
        summary = eng.summary()
    return tokens, summary


@pytest.fixture(params=REAL_ARCHS)
def real_variants(request):
    return {request.param: ARCHS[request.param].reduced()}


def _mixed_work(variants, rng):
    work = []
    for v in variants:
        for n_new in (4, 1, 6, 2):
            work.append((v, rng.integers(0, 200, size=(1, 3)), n_new))
    return work


def test_paged_tokens_bit_identical_to_generation(real_variants):
    rng = np.random.default_rng(0)
    work = _mixed_work(real_variants, rng)
    ref, _ = _run_workload(real_variants, "generation", work, n_slots=2)
    got, summary = _run_workload(
        real_variants, "paged", work,
        n_slots=3, block_size=8, prefill_chunk=2,
    )
    assert got == ref
    # occupancy telemetry flows for every paged pool; block occupancy only
    # for KV families (ssm pools have no blocks to meter)
    assert summary["slot_occupancy"]
    (cfg,) = real_variants.values()
    if cfg.family != "ssm":
        assert summary["block_occupancy"]
        occ = next(iter(summary["block_occupancy"].values()))
        assert 0.0 < occ["mean"] <= 1.0


def test_speculative_tokens_bit_identical_to_generation():
    variants = {"qwen2-0.5b": ARCHS["qwen2-0.5b"].reduced()}
    rng = np.random.default_rng(1)
    work = _mixed_work(variants, rng)
    ref, _ = _run_workload(variants, "generation", work, n_slots=2)
    got, summary = _run_workload(variants, "speculative", work, spec_k=3)
    assert got == ref
    sp = summary["spec_accept"]["spec:qwen2-0.5b"]
    assert sp["rounds"] > 0 and sp["drafted"] > 0
    assert 0.0 <= sp["rate"] <= 1.0


def test_engine_submit_validates_prompt_length():
    variants = {"qwen2-0.5b": ARCHS["qwen2-0.5b"].reduced()}
    with ServingEngine(variants, mode="paged", n_slots=2, cache_len=24,
                       block_size=8) as eng:
        # 22 prompt positions + 4 fed-back = 25 > cache_len 24
        with pytest.raises(PromptTooLongError):
            eng.submit("qwen2-0.5b", np.zeros((1, 22), np.int64), 4)
        with pytest.raises(PromptTooLongError):
            eng.submit("qwen2-0.5b", np.zeros((1, 0), np.int64), 4)
        # the engine still serves after the rejections
        tok = eng.submit(
            "qwen2-0.5b", np.array([[1, 2, 3]]), 2
        ).result(timeout=300).tokens
        assert len(tok) == 2
