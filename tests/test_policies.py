"""Scheduling-policy subsystem tests (DESIGN.md §3).

Three layers:

1. a deterministic **fake-clock harness** that drives ``SchedulingPolicy``
   instances through the same select/dispatch contract the real dispatcher
   uses, with simulated service times — no threads, no real sleeps;
2. a **recorded-trace equivalence** check: ``policy="fifo"`` must reproduce
   the exact dispatch order the seed (pre-refactor, thread-per-request)
   implementation produced on a single-threaded trace, captured verbatim
   below;
3. threaded integration checks: head-of-line-blocking avoidance under every
   registered policy, zero leaked threads after ``shutdown()``, and hedging
   loser exclusion.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque

import pytest

from repro.balancer import (
    LoadBalancer,
    PolicyContext,
    Request,
    Server,
    Telemetry,
    available_policies,
    create_policy,
)


# --------------------------------------------------------------------------
# 1. fake-clock harness
# --------------------------------------------------------------------------
def simulate(servers, policy, arrivals, service_time):
    """Run ``arrivals`` [(time, tag), ...] through ``policy`` on ``servers``.

    ``service_time(server, request) -> float`` is the simulated cost model.
    Returns ``(dispatch_order, requests)`` where dispatch_order is
    ``[(request_index, server_name), ...]`` in dispatch sequence.
    """
    policy = create_policy(policy)
    policy.reset()
    telemetry = Telemetry()
    clock = {"t": 0.0}
    ctx = PolicyContext(
        servers=servers, telemetry=telemetry, now=lambda: clock["t"]
    )
    for s in servers:  # sim timestamps start at 0, not time.monotonic()
        s.last_free_at = 0.0
    queue: deque = deque()
    running: list = []  # heap of (finish_time, seq, request, server)
    seq = itertools.count()
    order, requests = [], []
    arrivals = sorted(arrivals, key=lambda a: a[0])
    i = 0
    while i < len(arrivals) or queue or running:
        times = []
        if i < len(arrivals):
            times.append(arrivals[i][0])
        if running:
            times.append(running[0][0])
        if not times:
            raise RuntimeError("queued request no server can ever serve")
        t = clock["t"] = min(times)
        while running and running[0][0] <= t:
            _, _, req, server = heapq.heappop(running)
            req.completed_at = t
            server.busy = False
            server.last_free_at = t
            telemetry.record_completion(req, server)
        while i < len(arrivals) and arrivals[i][0] <= t:
            at, tag = arrivals[i]
            i += 1
            r = Request(theta=len(requests), tag=tag, arrived_at=at)
            requests.append(r)
            queue.append(r)
        while True:
            pair = policy.select(queue, ctx)
            if pair is None:
                break
            req, server = pair
            queue.remove(req)
            server.busy = True
            req.dispatched_at = t
            req.server = server.name
            order.append((req.theta, server.name))
            heapq.heappush(
                running, (t + service_time(server, req), next(seq), req, server)
            )
    return order, requests


def total_queue_delay(requests) -> float:
    return sum(r.dispatched_at - r.arrived_at for r in requests)


def heterogeneous_speed_pool():
    """Two fast + two slow generalist servers (speed gap 8x)."""
    servers = [
        Server(lambda x: x, name="fast-0"),
        Server(lambda x: x, name="fast-1"),
        Server(lambda x: x, name="slow-0"),
        Server(lambda x: x, name="slow-1"),
    ]
    speed = {"fast-0": 1.0, "fast-1": 1.0, "slow-0": 8.0, "slow-1": 8.0}
    base = {"heavy": 1.0, "light": 0.05}

    def service_time(server, req):
        return base[req.tag] * speed[server.name]

    return servers, service_time


def skewed_two_tag_arrivals(n=48, dt=0.25, heavy_every=4):
    """A light-dominated stream with periodic heavy solves (paper's regime:
    task costs spanning orders of magnitude)."""
    return [
        (k * dt, "heavy" if k % heavy_every == 0 else "light") for k in range(n)
    ]


# --------------------------------------------------------------------------
# 2. recorded seed trace (captured from the pre-refactor implementation)
# --------------------------------------------------------------------------
# Protocol used for the capture (single client thread):
#   * pool: any-0 (accepts all), pde-0 (tag 'pde'), gp-0 (tag 'gp');
#   * requests submitted one at a time in SEED_TAGS order, each visibly
#     enqueued/dispatched before the next (arrival order == submission
#     order); server fns block on per-request release events;
#   * completions released in SEED_RELEASE_ORDER, settling between releases.
SEED_TAGS = ["", "pde", "gp", "pde", "", "gp", "pde", "", "gp", "pde", "", ""]
SEED_RELEASE_ORDER = [0, 2, 1, 3, 5, 4, 6, 8, 7, 9, 10, 11]
SEED_EXPECTED_DISPATCH = [
    (0, "any-0"), (1, "pde-0"), (2, "gp-0"), (3, "any-0"), (5, "gp-0"),
    (6, "pde-0"), (4, "any-0"), (8, "gp-0"), (7, "any-0"), (9, "pde-0"),
    (10, "any-0"), (11, "any-0"),
]


def test_fifo_reproduces_seed_dispatch_order():
    dispatch_log = []
    log_lock = threading.Lock()
    releases = {i: threading.Event() for i in range(len(SEED_TAGS))}

    def make_fn(name):
        def fn(x):
            with log_lock:
                dispatch_log.append((x, name))
            releases[x].wait(10)
            return x

        return fn

    lb = LoadBalancer(
        [
            Server(make_fn("any-0"), name="any-0"),
            Server(make_fn("pde-0"), name="pde-0", capacity_tags=("pde",)),
            Server(make_fn("gp-0"), name="gp-0", capacity_tags=("gp",)),
        ],
        policy="fifo",
    )
    reqs = []
    for i, tag in enumerate(SEED_TAGS):
        r = lb.submit_async(i, tag=tag)
        reqs.append(r)
        deadline = time.monotonic() + 2
        while time.monotonic() < deadline:  # wait until enqueued or dispatched
            with lb._mutex:
                if r in lb._queue or r.dispatched_at:
                    break
            time.sleep(0.001)
        time.sleep(0.01)  # let any dispatch settle
    for i in SEED_RELEASE_ORDER:
        releases[i].set()
        assert reqs[i].done.wait(10)
        time.sleep(0.02)
    for r in reqs:
        lb.result(r, timeout=10)
    lb.shutdown()
    assert dispatch_log == SEED_EXPECTED_DISPATCH


# --------------------------------------------------------------------------
# policy behaviour on the fake clock
# --------------------------------------------------------------------------
def test_load_aware_policies_beat_round_robin_on_skewed_workload():
    """least_loaded and power_of_two must beat round_robin by total queue
    delay on a skewed two-tag workload over a speed-heterogeneous pool.

    Giving every server equal turns parks heavy solves on 8x-slower
    servers, burning capacity the backlog then pays for; load-aware
    policies route work toward the servers with the least accumulated
    busy time — i.e. the fast ones.
    """
    arrivals = skewed_two_tag_arrivals(n=64, dt=0.3, heavy_every=2)
    delays = {}
    for policy in ("round_robin", "least_loaded", "power_of_two"):
        servers, service_time = heterogeneous_speed_pool()
        _, requests = simulate(servers, policy, arrivals, service_time)
        assert all(r.dispatched_at >= r.arrived_at for r in requests)
        delays[policy] = total_queue_delay(requests)
    assert delays["round_robin"] > 0.5, "scenario failed to produce queueing"
    # robust margins (>20%) on this deterministic scenario, not ties
    assert delays["least_loaded"] < 0.8 * delays["round_robin"]
    assert delays["power_of_two"] < 0.8 * delays["round_robin"]


def test_cost_aware_routes_long_tags_to_fast_servers():
    """Once the EWMA cost model has data, cost_aware must not schedule a
    heavy solve on a slow server while a fast one is free."""
    arrivals = skewed_two_tag_arrivals()
    servers, service_time = heterogeneous_speed_pool()
    order, requests = simulate(servers, "cost_aware", arrivals, service_time)
    warm = {r.theta for r in requests[:8]}  # EWMA warm-up phase
    late_heavy = [
        (idx, srv)
        for idx, srv in order
        if requests[idx].tag == "heavy" and idx not in warm
    ]
    assert late_heavy, "scenario produced no post-warm-up heavy dispatches"
    frac_fast = sum(srv.startswith("fast") for _, srv in late_heavy) / len(late_heavy)
    assert frac_fast >= 0.8


def test_every_policy_is_deterministic_on_fake_clock():
    arrivals = skewed_two_tag_arrivals()
    for policy in available_policies():
        runs = []
        for _ in range(2):
            servers, service_time = heterogeneous_speed_pool()
            order, _ = simulate(servers, policy, arrivals, service_time)
            runs.append(order)
        assert runs[0] == runs[1], f"policy '{policy}' is nondeterministic"


def test_fifo_on_fake_clock_is_fifo_per_tag():
    servers, service_time = heterogeneous_speed_pool()
    order, requests = simulate(
        servers, "fifo", skewed_two_tag_arrivals(), service_time
    )
    for tag in ("heavy", "light"):
        dispatched = [i for i, _ in order if requests[i].tag == tag]
        assert dispatched == sorted(dispatched)


# --------------------------------------------------------------------------
# 3. threaded integration
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_no_head_of_line_blocking_under_every_policy(policy):
    """A queued fine-PDE request must not block a free GP server — the
    seed's heterogeneous-tag guarantee, now an invariant of every policy."""
    t_slow = 0.05

    def worker(duration):
        def fn(x):
            if duration:
                time.sleep(duration)
            return x * 2

        return fn

    lb = LoadBalancer(
        [
            Server(worker(t_slow), name="pde", capacity_tags=("pde",)),
            Server(worker(0.0), name="gp", capacity_tags=("gp",)),
        ],
        policy=policy,
    )
    r1 = lb.submit_async(1, tag="pde")
    time.sleep(0.005)
    r2 = lb.submit_async(2, tag="pde")
    t0 = time.monotonic()
    r3 = lb.submit_async(3, tag="gp")
    assert lb.result(r3) == 6
    gp_latency = time.monotonic() - t0
    assert gp_latency < t_slow / 2, "gp request stuck behind pde queue"
    assert (lb.result(r1), lb.result(r2)) == (2, 4)
    lb.shutdown()


@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_shutdown_leaks_no_threads(policy):
    baseline = threading.active_count()
    lb = LoadBalancer(
        [Server(lambda x: x, name=f"s{i}") for i in range(4)], policy=policy
    )
    reqs = [lb.submit_async(i) for i in range(32)]
    assert [lb.result(r) for r in reqs] == list(range(32))
    assert threading.active_count() > baseline  # engine actually ran threads
    lb.shutdown()
    assert threading.active_count() == baseline


def test_shutdown_fails_queued_requests():
    release = threading.Event()
    lb = LoadBalancer([Server(lambda x: release.wait(5) or x)])
    r1 = lb.submit_async(1)  # occupies the only server
    time.sleep(0.01)
    r2 = lb.submit_async(2)  # queued behind it

    t = threading.Thread(target=lb.shutdown)
    t.start()
    # shutdown fails the queued request while the in-flight one still runs
    assert r2.done.wait(2)
    with pytest.raises(RuntimeError, match="shut down"):
        lb.result(r2)
    release.set()  # let the in-flight request finish; shutdown can join
    t.join(5)
    assert not t.is_alive()
    assert lb.result(r1, timeout=1) == 1


def test_unservable_tag_rejected_at_submit():
    lb = LoadBalancer([Server(lambda x: x, capacity_tags=("gp",))])
    req = lb.submit_async(1, tag="pde")
    with pytest.raises(RuntimeError, match="no live server accepts"):
        lb.result(req, timeout=1)
    assert lb.submit(2, tag="gp") == 2  # servable traffic unaffected
    lb.shutdown()


def test_balanced_mlda_policy_threading():
    from repro.core import GaussianRandomWalk
    from repro.core.mlda import balanced_mlda

    servers = [Server(lambda t: t, name="s0")]
    sampler, lb = balanced_mlda(
        servers, lambda obs: 0.0, lambda t: 0.0, GaussianRandomWalk(0.1), [2],
        policy="least_loaded", level_tag=lambda lvl: "",
    )
    assert lb.policy.name == "least_loaded"
    assert sampler.balancer is lb
    # sharing an existing balancer: consistent policy ok, mismatch rejected
    sampler2, lb2 = balanced_mlda(
        lb, lambda obs: 0.0, lambda t: 0.0, GaussianRandomWalk(0.1), [2],
        policy="least_loaded",
    )
    assert lb2 is lb
    with pytest.raises(ValueError, match="runs 'least_loaded'"):
        balanced_mlda(
            lb, lambda obs: 0.0, lambda t: 0.0, GaussianRandomWalk(0.1), [2],
            policy="fifo",
        )
    with pytest.raises(ValueError, match="fixed at balancer construction"):
        balanced_mlda(
            lb, lambda obs: 0.0, lambda t: 0.0, GaussianRandomWalk(0.1), [2],
            max_retries=5,
        )
    lb.shutdown()


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        LoadBalancer([Server(lambda x: x)], policy="nope")


def test_registry_has_the_five_families():
    assert set(available_policies()) >= {
        "fifo", "round_robin", "least_loaded", "power_of_two", "cost_aware"
    }


def test_hedged_loser_excluded_even_when_backup_wins():
    """submit_hedged: first completion wins via a shared Event (no
    busy-poll) and the losing duplicate never enters idle-time stats."""
    slow_once = threading.Event()

    def fn(x):
        if x == "H" and not slow_once.is_set():
            slow_once.set()
            time.sleep(0.25)  # straggling primary
        else:
            time.sleep(0.001)
        return x

    lb = LoadBalancer(
        [Server(fn, name="a"), Server(fn, name="b")], hedge_quantile=0.9
    )
    for i in range(8):  # build runtime history
        lb.submit(i, tag="t")
    t0 = time.monotonic()
    assert lb.submit_hedged("H", tag="t") == "H"
    assert time.monotonic() - t0 < 0.2, "hedge did not rescue the straggler"
    # wait out the straggling primary, then check the books
    time.sleep(0.3)
    hedge_reqs = [r for r in lb.telemetry._history if r.theta == "H"]
    assert len(hedge_reqs) == 2
    assert sum(r.hedged for r in hedge_reqs) == 1, "exactly one loser flagged"
    winner = next(r for r in hedge_reqs if not r.hedged)
    assert winner.server is not None
    assert lb.summary()["n_requests"] == 9  # 8 history + 1 hedge winner
    lb.shutdown()
