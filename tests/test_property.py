"""Property-based tests (hypothesis) on system invariants."""
import time

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import HealthCheck, given, settings

from repro.balancer import LoadBalancer, Server
from repro.core.gp import GPParams, matern52
from repro.models.chunked_attention import attention_chunked
from repro.kernels.flash_attention.ref import attention_ref

FAST = settings(
    max_examples=15,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@FAST
@given(
    n_servers=st.integers(1, 4),
    durations=st.lists(st.floats(0.0, 0.004), min_size=1, max_size=20),
    fail_mask=st.lists(st.booleans(), min_size=0, max_size=4),
)
def test_balancer_never_loses_or_duplicates(n_servers, durations, fail_mask):
    """Every request completes exactly once with the right answer, as long
    as at least one live server exists (the paper's FCFS guarantee)."""
    fail_mask = (fail_mask + [False] * n_servers)[:n_servers]
    if all(fail_mask):
        fail_mask[0] = False  # keep one live server

    def mk(fails):
        def fn(x):
            if fails:
                raise RuntimeError("boom")
            time.sleep(0.0005)
            return ("ok", x)

        return fn

    lb = LoadBalancer(
        [Server(mk(f), name=f"s{i}") for i, f in enumerate(fail_mask)],
        max_retries=n_servers + 1,
    )
    reqs = [lb.submit_async(i) for i in range(len(durations))]
    results = [lb.result(r, timeout=30) for r in reqs]
    assert results == [("ok", i) for i in range(len(durations))]
    done = sum(s.stats.n_requests for s in lb.servers)
    assert done == len(durations)  # no duplicates on the success path


@FAST
@given(
    n=st.integers(2, 24),
    d=st.integers(1, 5),
    ls=st.floats(0.1, 3.0),
    scale=st.floats(0.1, 4.0),
    seed=st.integers(0, 2**16),
)
def test_matern_kernel_is_psd_and_bounded(n, d, ls, scale, seed):
    x = jax.random.normal(jax.random.key(seed), (n, d))
    p = GPParams(
        jnp.full((d,), np.log(ls)), jnp.asarray(np.log(scale)), jnp.zeros(())
    )
    k = np.asarray(matern52(x, x, p), dtype=np.float64)
    assert np.all(np.isfinite(k))
    assert np.all(k <= scale + 1e-5)  # k(x,x) is the max
    eig = np.linalg.eigvalsh((k + k.T) / 2)
    assert eig.min() > -1e-4 * scale


@FAST
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 4),
    s=st.sampled_from([16, 48, 64]),
    dd=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_chunked_attention_matches_oracle(b, h, s, dd, causal, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, dd))
    k = jax.random.normal(ks[1], (b, h, s, dd))
    v = jax.random.normal(ks[2], (b, h, s, dd))
    got = attention_chunked(q, k, v, causal=causal, block_k=16)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


@FAST
@given(
    amp=st.floats(0.5, 20.0),
    x0=st.floats(-180.0, 180.0),
    y0=st.floats(-180.0, 180.0),
)
def test_swe_positivity_and_finiteness(amp, x0, y0):
    """Water depth stays >= 0 and finite for any admissible source."""
    from repro.swe import TohokuScenario
    from repro.swe.solver import SWEState, stable_dt, step

    sc = TohokuScenario(nx=24, ny=24, t_end=600.0, amplitude=amp)
    cfg, b = sc.cfg, sc.bathymetry()
    h = jnp.maximum(jnp.maximum(-b, 0.0) + sc.displacement(jnp.array([x0, y0])), 0.0)
    stt = SWEState(h, jnp.zeros_like(h), jnp.zeros_like(h))
    dt = stable_dt(cfg, float(h.max()))
    for _ in range(10):
        stt = step(stt, b, cfg, dt)
    assert float(stt.h.min()) >= 0.0
    assert bool(jnp.all(jnp.isfinite(stt.h)))


@FAST
@given(
    seed=st.integers(0, 2**16),
    n_steps=st.integers(5, 40),
)
def test_mh_chain_logp_never_nan(seed, n_steps):
    from repro.core import GaussianRandomWalk, metropolis_hastings

    rng = np.random.default_rng(seed)
    banana = lambda t: float(-0.5 * (t[0] ** 2 + (t[1] - t[0] ** 2) ** 2))
    chain, logps, _ = metropolis_hastings(
        banana, GaussianRandomWalk(0.7), np.zeros(2), n_steps, rng
    )
    assert np.all(np.isfinite(logps))
    assert chain.shape == (n_steps, 2)
