"""Network-transparent serving tests (repro.net, DESIGN.md §11).

Hermetic by default: every connection is an in-process ``socketpair``
(``ServerShell.dial`` without a TCP bind), so the suite runs with no
network stack and deterministic timing.  Set ``REPRO_NET_TCP=1`` to run
the same tests over real loopback TCP sockets (CI does) — the transports
only see a dial callable, so nothing else changes.
"""
import os
import socket
import threading
import time

import numpy as np
import pytest

from repro.balancer import (
    BatchServer,
    LoadBalancer,
    RequestCancelled,
    Server,
    gather,
)
from repro.net import (
    ServerShell,
    TransportError,
    make_transport,
    recv_frame,
    remote_servers_for,
    send_frame,
)

USE_TCP = os.environ.get("REPRO_NET_TCP") == "1"


def _f(stacked):
    """The reference forward: rows of 2*theta + [0, 1, 2, ...] in fp32."""
    stacked = np.asarray(stacked, dtype=np.float32)
    return 2.0 * stacked + np.arange(
        stacked.shape[-1], dtype=np.float32
    )


def make_shell(servers, **kw):
    if USE_TCP:
        kw.setdefault("host", "127.0.0.1")
        kw.setdefault("port", 0)
    return ServerShell(servers, **kw).start()


def local_pool(check_finite=False):
    return [
        BatchServer(
            _f, name="pool-0", capacity_tags=("gp",), check_finite=check_finite
        )
    ]


@pytest.fixture
def leak_check():
    before = set(threading.enumerate())
    yield
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        leaked = [
            t for t in threading.enumerate()
            if t not in before and t.is_alive()
        ]
        if not leaked:
            return
        time.sleep(0.02)
    raise AssertionError(f"leaked threads: {[t.name for t in leaked]}")


# -- framing -----------------------------------------------------------------
def test_framing_roundtrip_bit_identical():
    a, b = socket.socketpair()
    try:
        arrays = [
            np.arange(12, dtype=np.float32).reshape(3, 4),
            np.array([], dtype="<f8"),
            (np.arange(5, dtype=np.int64) * -3),
        ]
        send_frame(a, {"op": "eval", "tag": "t"}, arrays)
        header, out = recv_frame(b)
        assert header["op"] == "eval" and header["tag"] == "t"
        assert len(out) == len(arrays)
        for sent, got in zip(arrays, out):
            assert got.dtype == np.dtype(sent.dtype.str).newbyteorder("=")
            assert got.shape == sent.shape
            assert got.tobytes() == np.ascontiguousarray(sent).tobytes()
        # clean EOF at a frame boundary -> (None, [])
        a.close()
        assert recv_frame(b) == (None, [])
    finally:
        b.close()


def test_framing_large_payload_crosses_whole():
    # Above SMALL_FRAME the arrays are written per-buffer (zero-copy path).
    a, b = socket.socketpair()
    got = {}

    def rx():
        got["frame"] = recv_frame(b)

    t = threading.Thread(target=rx)
    t.start()
    big = np.random.default_rng(0).random((512, 257)).astype(np.float32)
    send_frame(a, {"op": "eval_batch", "tag": "x"}, [big])
    t.join(5)
    header, arrays = got["frame"]
    assert arrays[0].shape == big.shape
    np.testing.assert_array_equal(arrays[0], big)
    a.close()
    b.close()


# -- binary transport: correctness ------------------------------------------
def test_remote_eval_bit_identical(leak_check):
    shell = make_shell(local_pool(), name="bit")
    with make_transport(shell, binary=True) as tr:
        theta = np.array([1.5, -2.25, 8.0], dtype=np.float32)
        row, service_s = tr.eval_single("gp", theta)
        expect = _f(theta[None])[0]
        assert row.tobytes() == expect.tobytes()  # fp32 bit-identity
        assert service_s >= 0.0
        stacked = np.linspace(-4, 4, 24, dtype=np.float32).reshape(8, 3)
        rows, _ = tr.eval_batch("gp", stacked)
        ref = _f(stacked)
        for i, r in enumerate(rows):
            assert r.tobytes() == ref[i].tobytes()
    shell.stop()


def test_info_reports_tags(leak_check):
    shell = make_shell(local_pool(), name="info")
    with make_transport(shell, binary=True) as tr:
        assert tr.info()["tags"] == ["gp"]
    shell.stop()


def test_member_error_scatter_over_the_wire(leak_check):
    # check_finite on the REMOTE side: the poisoned member comes back as a
    # FloatingPointError row, its batch mates bit-identical.
    shell = make_shell(local_pool(check_finite=True), name="scatter")
    with make_transport(shell, binary=True) as tr:
        stacked = np.ones((4, 3), dtype=np.float32)
        stacked[2] = np.nan
        rows, _ = tr.eval_batch("gp", stacked)
        assert isinstance(rows[2], FloatingPointError)
        ref = _f(stacked)
        for i in (0, 1, 3):
            assert rows[i].tobytes() == ref[i].tobytes()
    shell.stop()


def test_unknown_tag_is_a_call_error_not_transport_death(leak_check):
    shell = make_shell(local_pool(), name="badtag")
    with make_transport(shell, binary=True) as tr:
        with pytest.raises((KeyError, RuntimeError)):
            tr.eval_single("nope", np.zeros(3, dtype=np.float32))
        # the connection survived: a good call still works
        row, _ = tr.eval_single("gp", np.zeros(3, dtype=np.float32))
        assert row.shape == (3,)
    shell.stop()


def test_pipelining_many_inflight_one_connection(leak_check):
    delay = 0.05
    n = 8

    def slow(stacked):
        time.sleep(delay)
        return _f(stacked)

    # n replica servers: the shell serializes calls per server (the
    # one-worker-per-server discipline) but runs different replicas
    # concurrently, so n pipelined frames on ONE connection overlap.
    shell = make_shell(
        [
            BatchServer(slow, name=f"s{i}", capacity_tags=("gp",))
            for i in range(n)
        ],
        name="pipe",
        max_workers=n,
    )
    with make_transport(shell, binary=True, n_connections=1) as tr:
        results = [None] * n
        t0 = time.monotonic()

        def call(i):
            theta = np.full(3, float(i), dtype=np.float32)
            results[i] = tr.eval_single("gp", theta)[0]

        threads = [threading.Thread(target=call, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(10)
        wall = time.monotonic() - t0
    shell.stop()
    for i, row in enumerate(results):
        expect = _f(np.full((1, 3), float(i), dtype=np.float32))[0]
        assert row.tobytes() == expect.tobytes()
    # n serial round trips cost >= n * delay even with an instant wire;
    # pipelined on one connection they overlap across the replicas.
    assert wall < 0.5 * n * delay, f"not pipelined: {wall:.3f}s"


# -- UM-Bridge JSON interop ---------------------------------------------------
def test_json_transport_matches_binary(leak_check):
    shell = make_shell(local_pool(), name="json")
    theta = np.array([0.5, 1.5, -3.0], dtype=np.float32)
    with make_transport(shell, binary=True) as btr:
        bin_row, _ = btr.eval_single("gp", theta)
    with make_transport(shell, binary=False) as jtr:
        assert jtr.info()["tags"] == ["gp"]
        json_row, _ = jtr.eval_single("gp", theta)
        np.testing.assert_allclose(json_row, bin_row, rtol=1e-6)
    shell.stop()


def test_json_member_errors_cross_as_memberErrors(leak_check):
    shell = make_shell(local_pool(check_finite=True), name="json-err")
    with make_transport(shell, binary=False) as jtr:
        stacked = np.ones((3, 3), dtype=np.float32)
        stacked[1] = np.inf
        rows, _ = jtr.eval_batch("gp", stacked)
        assert isinstance(rows[1], FloatingPointError)
        ref = _f(stacked)
        np.testing.assert_allclose(rows[0], ref[0], rtol=1e-6)
        np.testing.assert_allclose(rows[2], ref[2], rtol=1e-6)
    shell.stop()


def test_umbridge_http_with_stdlib_client(leak_check):
    # A foreign UM-Bridge client is plain HTTP: use http.client directly.
    if not USE_TCP:
        pytest.skip("stdlib http.client needs a real TCP endpoint")
    import http.client
    import json as _json

    shell = make_shell(local_pool(), name="umb")
    host, port = shell.address
    conn = http.client.HTTPConnection(host, port, timeout=10)
    try:
        conn.request("GET", "/Info")
        info = _json.loads(conn.getresponse().read())
        assert info["models"] == ["gp"]
        body = _json.dumps({"name": "gp", "input": [[1.0, 2.0, 3.0]]})
        conn.request("POST", "/Evaluate", body=body)
        out = _json.loads(conn.getresponse().read())
        np.testing.assert_allclose(
            out["output"][0], _f(np.array([[1.0, 2.0, 3.0]]))[0], rtol=1e-6
        )
    finally:
        conn.close()
    shell.stop()


# -- through the dispatcher ---------------------------------------------------
def test_balancer_over_remote_bit_identical_to_inprocess(leak_check):
    thetas = np.random.default_rng(1).random((24, 3)).astype(np.float32)
    # in-process reference
    with LoadBalancer(local_pool()) as lb:
        ref = [lb.submit(t, tag="gp", batchable=True) for t in thetas]
    shell = make_shell(local_pool(), name="via-lb")
    tr = make_transport(shell, binary=True)
    remotes = remote_servers_for(tr, max_batch=8)
    with LoadBalancer(remotes, batch_window_s=0.002, max_batch=8) as lb:
        reqs = lb.submit_many(list(thetas), tag="gp", batchable=True)
        gather(reqs)
        for req, expect in zip(reqs, ref):
            assert req.error is None
            assert req.result.tobytes() == expect.tobytes()
    tr.close()
    shell.stop()


def test_wire_split_telemetry_booked(leak_check):
    shell = make_shell(local_pool(), name="wire")
    tr = make_transport(shell, binary=True)
    with LoadBalancer(remote_servers_for(tr)) as lb:
        for i in range(8):
            lb.submit(np.full(3, float(i), dtype=np.float32), tag="gp")
        split = lb.summary()["wire_split"]
        assert len(split) == 1
        (stats,) = split.values()
        assert stats["calls"] == 8
        assert stats["wire_s"] >= 0.0 and stats["service_s"] > 0.0
        (row,) = lb.stats_table()
        assert row["wire_ewma_s"] is not None
    tr.close()
    shell.stop()


def test_server_death_mid_batch_requeues_on_survivor(leak_check):
    """Kill a remote shell mid-batch: every in-flight member must requeue
    and complete on the surviving replica, retries bounded, no leaks."""
    release = threading.Event()
    entered = threading.Event()

    def doomed(stacked):
        entered.set()
        release.wait(5)
        return _f(stacked)  # never ships: the socket is reset first

    shell_a = make_shell(
        [BatchServer(doomed, name="a0", capacity_tags=("gp",))], name="doomed"
    )
    shell_b = make_shell(local_pool(), name="survivor")
    tr_a = make_transport(shell_a, binary=True, retries=0)
    tr_b = make_transport(shell_b, binary=True)
    ra = remote_servers_for(tr_a, tags=["gp"], name_prefix="ra")[0]
    rb = remote_servers_for(tr_b, tags=["gp"], name_prefix="rb")[0]
    lb = LoadBalancer([ra, rb], batch_window_s=0.01, max_batch=8, max_retries=2)
    thetas = np.arange(24, dtype=np.float32).reshape(8, 3)
    reqs = lb.submit_many(list(thetas), tag="gp", batchable=True)
    assert entered.wait(5), "doomed shell never got a batch"
    shell_a.kill()  # machine loss: sockets reset, in-flight results lost
    release.set()
    gather(reqs, timeout=20)
    ref = _f(thetas)
    for i, req in enumerate(reqs):
        assert req.error is None, f"member {i}: {req.error}"
        assert req.result.tobytes() == ref[i].tobytes()
        assert req.retries <= lb.max_retries
    assert ra.dead and not rb.dead
    assert any(r.retries > 0 for r in reqs)  # the killed members retried
    lb.shutdown()
    tr_a.close()
    tr_b.close()
    shell_b.stop()


def test_transport_retry_then_exhaustion(leak_check):
    shell = make_shell(local_pool(), name="gone")
    tr = make_transport(shell, binary=True, retries=1, backoff_s=0.01)
    row, _ = tr.eval_single("gp", np.zeros(3, dtype=np.float32))
    assert row.shape == (3,)
    shell.kill()
    with pytest.raises(TransportError):
        tr.eval_single("gp", np.zeros(3, dtype=np.float32))
    tr.close()


# -- client-side deadlines -----------------------------------------------------
def test_cancel_queued_request(leak_check):
    gate = threading.Event()

    def slow(theta):
        gate.wait(5)
        return theta

    with LoadBalancer([Server(slow, name="s")]) as lb:
        r1 = lb.submit_async(1.0)
        time.sleep(0.05)  # let r1 reach the server
        r2 = lb.submit_async(2.0)
        assert r2.cancel() is True
        assert isinstance(r2.error, RequestCancelled)
        assert r2.done.is_set()
        assert r2.cancel() is False  # idempotent: already completed
        gate.set()
        assert lb.result(r1, timeout=5) == 1.0
        assert r1.cancel() is False  # completed requests cannot cancel


def test_gather_deadline_cancels_pending(leak_check):
    gate = threading.Event()

    def slow(theta):
        gate.wait(5)
        return theta

    with LoadBalancer([Server(slow, name="s")]) as lb:
        reqs = [lb.submit_async(float(i)) for i in range(4)]
        with pytest.raises(TimeoutError):
            gather(reqs, timeout=0.1, cancel_pending=True)
        # the in-flight head is abandoned (still running), the queued tail
        # was reclaimed with RequestCancelled
        cancelled = [r for r in reqs if isinstance(r.error, RequestCancelled)]
        assert len(cancelled) == 3
        gate.set()
        assert lb.result(reqs[0], timeout=5) == 0.0


def test_result_cancel_on_timeout(leak_check):
    gate = threading.Event()

    def slow(theta):
        gate.wait(5)
        return theta

    with LoadBalancer([Server(slow, name="s")]) as lb:
        r1 = lb.submit_async(1.0)
        time.sleep(0.05)
        r2 = lb.submit_async(2.0)
        with pytest.raises(TimeoutError):
            lb.result(r2, timeout=0.05, cancel_on_timeout=True)
        assert isinstance(r2.error, RequestCancelled)
        gate.set()
        assert lb.result(r1, timeout=5) == 1.0


def test_remote_deadline_abandons_cleanly(leak_check):
    # A request timing out over the wire kills that connection (the
    # pipelined stream can't resync) but the transport redials: the next
    # call succeeds and nothing leaks.
    release = threading.Event()

    def stall(stacked):
        release.wait(2)
        return _f(stacked)

    shell = make_shell(
        [BatchServer(stall, name="s", capacity_tags=("gp",))], name="stall",
        max_workers=4,
    )
    tr = make_transport(shell, binary=True, retries=0)
    with pytest.raises(TransportError):
        tr.eval_single("gp", np.zeros(3, dtype=np.float32), timeout=0.05)
    release.set()
    row, _ = tr.eval_single("gp", np.zeros(3, dtype=np.float32), timeout=5)
    assert row.shape == (3,)
    tr.close()
    shell.stop()


# -- lifecycle ----------------------------------------------------------------
def test_graceful_drain_ships_inflight_results(leak_check):
    started = threading.Event()

    def slowish(stacked):
        started.set()
        time.sleep(0.1)
        return _f(stacked)

    shell = make_shell(
        [BatchServer(slowish, name="s", capacity_tags=("gp",))], name="drain"
    )
    tr = make_transport(shell, binary=True)
    out = {}

    def call():
        out["row"] = tr.eval_single("gp", np.ones(3, dtype=np.float32))[0]

    t = threading.Thread(target=call)
    t.start()
    assert started.wait(5)
    shell.stop(drain=True)  # must wait for the in-flight eval to ship
    t.join(5)
    expect = _f(np.ones((1, 3), dtype=np.float32))[0]
    assert out["row"].tobytes() == expect.tobytes()
    tr.close()


def test_drain_deadline_abandons_stuck_handler(leak_check):
    """A wedged handler cannot park stop(): past the drain deadline the
    shell resets its sockets and abandons the worker (satellite of
    DESIGN.md §12's fault model)."""
    entered = threading.Event()
    release = threading.Event()

    def stuck(stacked):
        entered.set()
        release.wait(30)
        return _f(stacked)

    shell = make_shell(
        [BatchServer(stuck, name="s", capacity_tags=("gp",))], name="stuck"
    )
    tr = make_transport(shell, binary=True, retries=0)

    def call():
        try:
            tr.eval_single("gp", np.ones(3, dtype=np.float32))
        except (TransportError, ConnectionError):
            pass  # the client sees a clean connection loss

    t = threading.Thread(target=call)
    t.start()
    try:
        assert entered.wait(5)
        t0 = time.monotonic()
        shell.stop(drain=True, timeout=0.2)  # handler never returns
        assert time.monotonic() - t0 < 5.0, "stop() parked on a wedged handler"
        t.join(5)
        assert not t.is_alive()
    finally:
        release.set()  # unwedge the abandoned worker so it can run out
        t.join(5)
        tr.close()


# -- health probes over the wire ----------------------------------------------
def test_probe_heartbeat_binary_and_json(leak_check):
    shell = make_shell(local_pool(), name="probe")
    with make_transport(shell, binary=True) as btr:
        assert btr.probe()
    with make_transport(shell, binary=False) as jtr:
        assert jtr.probe()
    shell.stop()


def test_remote_server_probe_tracks_shell_liveness(leak_check):
    shell = make_shell(local_pool(), name="probe-live")
    tr = make_transport(shell, binary=True)
    server = remote_servers_for(tr)[0]
    assert server.probe()  # alive: the heartbeat frame round-trips
    shell.kill()
    assert not server.probe()  # dead: single attempt, no retry ladder
    tr.close()


def test_probe_does_not_disturb_pipelined_traffic(leak_check):
    delay = 0.05

    def slow(stacked):
        time.sleep(delay)
        return _f(stacked)

    shell = make_shell(
        [BatchServer(slow, name="s", capacity_tags=("gp",))], name="probe-mix"
    )
    with make_transport(shell, binary=True, n_connections=1) as tr:
        out = {}

        def call():
            out["row"] = tr.eval_single("gp", np.ones(3, dtype=np.float32))[0]

        t = threading.Thread(target=call)
        t.start()
        time.sleep(delay / 5)
        # probe answered from the frame loop while the eval is in flight
        assert tr.probe()
        t.join(5)
        expect = _f(np.ones((1, 3), dtype=np.float32))[0]
        assert out["row"].tobytes() == expect.tobytes()
    shell.stop()


# -- redial backoff: capped + jittered ----------------------------------------
def test_backoff_delays_capped_and_jittered_downward(monkeypatch):
    from repro.net import BinaryTransport

    def refuse():
        raise OSError("connection refused")

    delays = []
    monkeypatch.setattr(time, "sleep", delays.append)
    tr = BinaryTransport(
        refuse, retries=4, backoff_s=0.1, backoff_cap_s=0.25, backoff_jitter=0.5
    )
    with pytest.raises(TransportError):
        tr.eval_single("gp", np.zeros(3, dtype=np.float32))
    # deterministic schedule 0.1, 0.2, 0.4->cap, 0.8->cap; jitter only
    # shortens (never lengthens) each delay, by at most backoff_jitter.
    schedule = [0.1, 0.2, 0.25, 0.25]
    assert len(delays) == len(schedule)
    for observed, nominal in zip(delays, schedule):
        assert 0.5 * nominal <= observed <= nominal
    tr.close()


def test_deprecated_core_balancer_shim_warns():
    import importlib
    import sys
    import warnings

    sys.modules.pop("repro.core.balancer", None)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        mod = importlib.import_module("repro.core.balancer")
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    assert mod.LoadBalancer is LoadBalancer
