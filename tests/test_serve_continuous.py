"""Continuous-batching LM serving engine tests (DESIGN.md §10).

Fast layers (fake step functions, no device work) cover the DecodePool
slot lifecycle, FIFO admission, telemetry and failure semantics; two
real-model tests pin the numerical contracts — fused prefill vs the
teacher-forcing loop, and pooled continuous decode vs sequential B=1
decode — on a dense and an SSM family (the vmap-batch-invariance fix in
models/ssm.py is what makes the latter hold for mamba).
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.balancer import (
    DecodeHandoff,
    DecodePool,
    LoadBalancer,
    ServerDiedError,
)
from repro.configs import ARCHS
from repro.models import build_model
from repro.models.lm import decode_step, init_decode_state, prefill_state
from repro.runtime.serve_loop import ServingEngine


# ---------------------------------------------------------------------------
# Fake-pool fixtures: the slot lifecycle without device work
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def counting_pool(n_slots=4, clock=None, **kw):
    """A DecodePool whose 'model' emits token+1 each step."""

    def step_fn(state, toks):
        return state + 1, toks + 1

    return DecodePool(
        step_fn, lambda st, slot, seq: st, lambda: 0, n_slots,
        clock=clock or FakeClock(), **kw,
    )


def handoff(token, max_new, eos=None):
    return DecodeHandoff(state=None, token=token, max_new=max_new, eos=eos)


def test_slot_eviction_on_max_length_and_eos():
    pool = counting_pool(n_slots=4)
    lb = LoadBalancer([pool])
    # max-length eviction: budget 3 -> handoff token + 2 steps
    r_len = lb.submit_async(handoff(10, 3), tag="")
    # EOS eviction: token 41 -> 42 == eos stops a budget-10 request early
    r_eos = lb.submit_async(handoff(41, 10, eos=42), tag="")
    assert lb.result(r_len, timeout=5).tokens.tolist() == [10, 11, 12]
    assert lb.result(r_eos, timeout=5).tokens.tolist() == [41, 42]
    # both slots were evicted back to the free list
    assert pool.n_free == pool.n_slots
    lb.shutdown()


def test_instant_finish_never_touches_device_state():
    built = []

    def init_state():
        built.append(1)
        return 0

    pool = DecodePool(
        lambda st, t: (st, t + 1), lambda st, slot, seq: st, init_state, 2,
        clock=FakeClock(),
    )
    lb = LoadBalancer([pool])
    # budget 1: the prefill already produced the only token
    assert lb.result(lb.submit_async(handoff(7, 1)), timeout=5).tokens.tolist() == [7]
    # handoff token == eos: finished at admission too
    assert lb.result(
        lb.submit_async(handoff(9, 8, eos=9)), timeout=5
    ).tokens.tolist() == [9]
    assert not built, "instant-finish admissions must not allocate pool state"
    lb.shutdown()


def test_fifo_admission_order_and_token_boundary_join():
    clock = FakeClock()
    pool = counting_pool(n_slots=2, clock=clock)
    lb = LoadBalancer([pool])
    # Two long generations fill both slots; two more queue behind them and
    # must join in arrival order as slots free at token boundaries.
    first = [lb.submit_async(handoff(100 * i, 3)) for i in (1, 2)]
    later = [lb.submit_async(handoff(100 * i, 2)) for i in (3, 4)]
    for r in first + later:
        lb.result(r, timeout=5)
    order = [req for _, req in pool.admit_log]
    assert order == first + later, "admission must be FIFO across joins"
    # the joiners reused the two slots
    assert sorted(slot for slot, _ in pool.admit_log) == [0, 0, 1, 1]
    lb.shutdown()


def test_pool_death_fails_in_flight_without_retry():
    calls = []

    def dying_step(state, toks):
        calls.append(1)
        if len(calls) >= 2:
            raise RuntimeError("device lost")
        return state, toks + 1

    pool = DecodePool(
        dying_step, lambda st, slot, seq: st, lambda: 0, 2, clock=FakeClock()
    )
    lb = LoadBalancer([pool], max_retries=2)
    req = lb.submit_async(handoff(5, 10))
    with pytest.raises(ServerDiedError):
        lb.result(req, timeout=5)
    assert req.retries == 0, "continuous requests must not retry (state died)"
    assert pool.dead
    lb.shutdown()


def test_no_leaked_threads_after_shutdown():
    baseline = threading.active_count()
    pool = counting_pool(n_slots=2)
    lb = LoadBalancer([pool])
    reqs = [lb.submit_async(handoff(i, 4)) for i in range(6)]
    for r in reqs:
        lb.result(r, timeout=5)
    lb.shutdown()
    assert threading.active_count() == baseline


def test_token_telemetry_and_stats_table():
    pool = counting_pool(n_slots=4, capacity_tags=["decode:x"])
    lb = LoadBalancer([pool])
    reqs = [lb.submit_async(handoff(0, n), tag="decode:x") for n in (3, 1, 5)]
    for r in reqs:
        lb.result(r, timeout=5)
    s = lb.summary()
    # emitted = generated minus the handoff tokens: (3-1) + 0 + (5-1)
    assert s["tag_tokens"] == {"decode:x": 6}
    occ = s["slot_occupancy"][pool.name]
    assert occ["capacity"] == 4
    assert 0 < occ["mean"] <= 1
    (row,) = [r for r in lb.stats_table() if r["tag"] == "decode:x"]
    assert row["n_done"] == 3
    assert row["tokens"] == 6
    lb.shutdown()


# ---------------------------------------------------------------------------
# Real-model numerical contracts
# ---------------------------------------------------------------------------
REAL_ARCHS = ["qwen2-0.5b", "mamba2-1.3b"]
CACHE_LEN = 24


@pytest.fixture(scope="module", params=REAL_ARCHS)
def model(request):
    cfg = ARCHS[request.param].reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_prefill_state_matches_teacher_forcing_loop(model):
    """Satellite 1: the fused scan prefill IS the per-token loop, bitwise."""
    cfg, bundle, params = model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=(1, 6))
    logits_f, state_f = jax.jit(
        lambda p, t: prefill_state(p, cfg, t, CACHE_LEN)
    )(params, jnp.asarray(prompt, jnp.int32))

    state = init_decode_state(cfg, 1, CACHE_LEN)
    step = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))
    for t in range(prompt.shape[1]):
        logits_l, state = step(params, state, jnp.asarray(prompt[:, t : t + 1], jnp.int32))

    assert int(jnp.argmax(logits_f[0, -1])) == int(jnp.argmax(logits_l[0, -1]))
    assert int(state_f.pos) == int(state.pos) == prompt.shape[1]


def test_continuous_tokens_bit_identical_to_sequential(model):
    """The tentpole contract: slot-pooled continuous decode emits exactly
    the tokens of sequential one-request-at-a-time decode."""
    cfg, bundle, params = model
    name = cfg.arch_id
    rng = np.random.default_rng(2)
    work = [
        (rng.integers(0, cfg.vocab, size=(1, 4)), n_new)
        for n_new in (5, 1, 3, 7, 2, 4)
    ]
    outs = {}
    for mode in ("continuous", "generation"):
        with ServingEngine(
            {name: cfg}, mode=mode, n_slots=3, cache_len=CACHE_LEN
        ) as eng:
            gens = [eng.submit(name, p, n) for p, n in work]
            outs[mode] = [g.result(timeout=120).tokens for g in gens]
            if mode == "continuous":
                s = eng.summary()
                assert sum(s["tag_tokens"].values()) > 0
                assert s["slot_occupancy"]
    for a, b in zip(outs["continuous"], outs["generation"]):
        assert np.array_equal(a, b)
    for (_, n_new), toks in zip(work, outs["continuous"]):
        assert len(toks) == n_new
