"""ShardedBatchServer vs BatchServer: same results, same error scatter.

The sharded pool must be a drop-in replacement for a plain batch server:
bitwise-equal results (both sides run through the same AOT jit path —
eager-vs-jit FMA contraction would otherwise differ by 1 ulp), identical
per-member ``check_finite`` failure scatter, and graceful fallback to an
unsharded call when the pow2-padded batch does not divide the mesh.

CI runs this file twice: once on the default single-device CPU backend
(tier-1) and once with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in a separate process (XLA_FLAGS is read at jax init, so the forced mesh
cannot be set from inside an already-running suite).  The 8-device-only
assertions gate themselves on ``len(jax.devices())``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.balancer import BatchServer, ShardedBatchServer
from repro.runtime.sharding import data_mesh, data_policy
from repro.swe.solver import AOTBatchCache


def stacked_fn(stacked):
    """(B, 3) -> (B, 2): includes a transcendental so fast-math or
    recomputation differences would show up in the bits."""
    q = jnp.sum(stacked * stacked, axis=-1)
    return jnp.stack([q, jnp.exp(-0.5 * q)], axis=-1)


def aot_matched_plain(fn, name):
    """A BatchServer whose handler runs through the same AOTBatchCache jit
    path as the sharded pool — the fair bitwise baseline."""
    aot = AOTBatchCache(fn, key=("test-plain", name), dtype=None, pad="repeat")

    def run(stacked):
        out, n = aot(stacked)
        return jax.tree.map(lambda x: np.asarray(x)[:n], out)

    return BatchServer(run, name=f"plain-{name}")


@pytest.mark.parametrize("batch", [1, 3, 8, 11, 16, 64])
def test_sharded_matches_plain_bitwise(batch):
    policy = data_policy()
    sharded = ShardedBatchServer(
        stacked_fn, policy, name="pool", cache_key=("test", batch)
    )
    plain = aot_matched_plain(stacked_fn, f"b{batch}")
    rng = np.random.default_rng(batch)
    thetas = [rng.normal(size=3).astype(np.float32) for _ in range(batch)]
    got = sharded.batch_call(thetas)
    want = plain.batch_call(thetas)
    assert len(got) == len(want) == batch
    for g, w in zip(got, want):
        assert np.array_equal(
            np.asarray(g).view(np.uint32), np.asarray(w).view(np.uint32)
        )


def test_indivisible_batch_falls_back_unsharded():
    """B=3 pads to 4; on an 8-device mesh 4 < |mesh| so batch_axes is None
    and the pool must take the unsharded path — correctness either way."""
    policy = data_policy()
    n_dev = len(jax.devices())
    if n_dev >= 8:
        assert policy.batch_axes(4) is None
        assert policy.batch_axes(8) is not None
        assert policy.batch_axes(64) is not None
    sharded = ShardedBatchServer(
        stacked_fn, policy, name="pad-pool", cache_key=("test", "pad")
    )
    thetas = [np.full(3, 0.25 * (i + 1), np.float32) for i in range(3)]
    got = sharded.batch_call(thetas)
    want = aot_matched_plain(stacked_fn, "pad").batch_call(thetas)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w))


def test_forced_mesh_spans_devices():
    """When the 8-device mesh is forced, the policy really shards over it."""
    if len(jax.devices()) < 8:
        pytest.skip("single-device backend; forced-mesh CI step covers this")
    mesh = data_mesh(8)
    assert mesh.devices.size == 8
    policy = data_policy(mesh)
    assert policy.batch_axes(16) == tuple(mesh.axis_names)


def test_check_finite_scatters_per_member():
    """One poisoned member fails alone; batch mates still get results."""
    policy = data_policy()
    sharded = ShardedBatchServer(
        stacked_fn,
        policy,
        name="nan-pool",
        check_finite=True,
        cache_key=("test", "nan"),
    )
    thetas = [np.ones(3, np.float32) * 0.1 for _ in range(8)]
    thetas[5] = np.array([np.nan, 0.0, 0.0], np.float32)
    results = sharded.batch_call(thetas)
    assert isinstance(results[5], FloatingPointError)
    for i, r in enumerate(results):
        if i != 5:
            assert np.all(np.isfinite(np.asarray(r)))


def test_make_level_servers_wires_one_sharded_pool():
    """With a policy + stacked forwards, a level gets ONE sharded pool
    instead of ``servers_per_level`` BatchServer replicas."""
    import dataclasses

    from repro.configs.tohoku_mlda import CPU
    from repro.swe import make_level_servers

    w = dataclasses.replace(CPU, batch_solves=True)

    def gp(t):
        return jnp.sum(t)

    gp.batch_call = stacked_fn
    servers = make_level_servers(
        w,
        gp,
        stacked_fn,
        stacked_fn,
        stacked_forwards=(None, stacked_fn, stacked_fn),
        policy=data_policy(),
    )
    pools = [s for s in servers if isinstance(s, ShardedBatchServer)]
    assert sorted(p.name for p in pools) == ["coarse-pool", "fine-pool", "gp-0"]
    assert {next(iter(p.capacity_tags)) for p in pools} == {
        "level0",
        "level1",
        "level2",
    }
    assert len(servers) == 3  # replicas replaced by one pool per level

    # Setting the config's mesh_devices knob alone (no explicit policy)
    # derives the mesh in make_level_servers — the GP pool shards.
    w_mesh = dataclasses.replace(CPU, batch_solves=True, mesh_devices=1)
    servers = make_level_servers(w_mesh, gp, stacked_fn, stacked_fn)
    assert isinstance(servers[0], ShardedBatchServer)
