"""Sharding policy unit tests (no multi-device needed — pure spec logic)."""
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES


class FakeMesh:
    """Duck-typed mesh: ShardingPolicy only reads .shape and .axis_names."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)


def _policy(pure_dp=False, shape=(("data", 16), ("model", 16))):
    from repro.runtime.sharding import ShardingPolicy

    mesh = FakeMesh(shape)
    if pure_dp:
        return ShardingPolicy(mesh=mesh, dp_axes=("data", "model"), model_axis=None)
    return ShardingPolicy(mesh=mesh, dp_axes=("data",))


def test_shard_if_divisibility():
    p = _policy()
    assert p.shard_if(32, "model") == "model"
    assert p.shard_if(14, "model") is None
    assert p.shard_if(0, "model") == "model"  # 0 % 16 == 0 (degenerate)


def test_batch_axes_fallback_chain():
    p = _policy(pure_dp=True)
    assert p.batch_axes(256) == ("data", "model")
    assert p.batch_axes(128) == ("data",)  # drops 'model'
    assert p.batch_axes(7) is None


def test_param_spec_tp_rules():
    from repro.runtime.sharding import param_spec

    p = _policy()

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    # embed (V, d): vocab on model, d on dp
    spec = param_spec(p, [_K("embed")], Leaf((32000, 4096)))
    assert spec == P("model", ("data",))
    # mlp w_up (L, d, ff): ff on model, d on dp
    spec = param_spec(p, [_K("blocks"), _K("mlp"), _K("w_up")], Leaf((32, 4096, 14336)))
    assert spec == P(None, ("data",), "model")
    # wo (L, heads*hd, d): contract dim on model, d_model on dp
    spec = param_spec(p, [_K("blocks"), _K("attn"), _K("wo")], Leaf((32, 4096, 4096)))
    assert spec == P(None, "model", ("data",))
    # norms replicate
    spec = param_spec(p, [_K("ln1")], Leaf((4096,)))
    assert spec == P(None)
    # indivisible out dim falls back to replication; FSDP in-dim kept
    spec = param_spec(p, [_K("blocks"), _K("attn"), _K("wq")], Leaf((24, 896, 897)))
    assert spec == P(None, ("data",), None)


def test_param_spec_pure_dp_largest_dim():
    from repro.runtime.sharding import param_spec

    p = _policy(pure_dp=True)

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    spec = param_spec(p, [_K("blocks"), _K("mlp"), _K("w_up")], Leaf((32, 896, 4864)))
    # 4864 % 256 = 0 -> largest divisible dim sharded over all axes
    assert spec == P(None, None, ("data", "model"))


def test_choose_policy_families():
    from repro.runtime.sharding import choose_policy

    mesh = FakeMesh((("data", 16), ("model", 16)))
    # small dense -> pure DP for training
    pol = choose_policy(ARCHS["qwen2-0.5b"], SHAPES["train_4k"], mesh)
    assert pol.model_axis is None
    # big + divisible heads + no MoE -> TP
    pol = choose_policy(ARCHS["nemotron-4-340b"], SHAPES["train_4k"], mesh)
    assert pol.model_axis == "model" and pol.seq_parallel
    # MoE with E % 16 != 0 -> pure DP even at 141B (measured; §Perf)
    pol = choose_policy(ARCHS["mixtral-8x22b"], SHAPES["train_4k"], mesh)
    assert pol.model_axis is None
    # decode always TP-side
    pol = choose_policy(ARCHS["qwen2-0.5b"], SHAPES["decode_32k"], mesh)
    assert pol.model_axis == "model"


class _K:
    def __init__(self, key):
        self.key = key

    def __repr__(self):
        return self.key
