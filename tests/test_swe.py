"""Shallow-water solver tests (paper §3 requirements)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.swe import TohokuScenario, lake_at_rest_error
from repro.swe.solver import SWEConfig, SWEState, desingularized_velocity, make_solver, stable_dt, step


def test_lake_at_rest_exact():
    """Well-balancedness (paper §3.2): fp32-exact with the deviation form."""
    sc = TohokuScenario(nx=48, ny=48, t_end=600.0)
    assert lake_at_rest_error(sc.cfg, sc.bathymetry(), n_steps=40) < 1e-3


def test_positivity_no_nan_large_displacement():
    sc = TohokuScenario(nx=48, ny=48, t_end=1800.0, amplitude=25.0)
    fwd = jax.jit(sc.build_forward())
    obs = fwd(jnp.array([0.0, 0.0]))
    assert bool(jnp.all(jnp.isfinite(obs)))


def test_depth_stays_nonnegative():
    sc = TohokuScenario(nx=32, ny=32, t_end=900.0, amplitude=15.0)
    cfg, b = sc.cfg, sc.bathymetry()
    h = jnp.maximum(-b, 0.0) + sc.displacement(jnp.array([0.0, 0.0]))
    st = SWEState(jnp.maximum(h, 0.0), jnp.zeros_like(h), jnp.zeros_like(h))
    dt = stable_dt(cfg, float(h.max()))
    for _ in range(30):
        st = step(st, b, cfg, dt)
    assert float(st.h.min()) >= 0.0


def test_mirror_symmetry():
    """Symmetric bathymetry + centred source => y-mirror-symmetric solution."""
    cfg = SWEConfig(nx=40, ny=40, dx=10e3, dy=10e3, t_end=600.0)
    b = jnp.full((40, 40), -4000.0)
    xc = jnp.arange(40) - 19.5
    X, Y = jnp.meshgrid(xc, xc)
    eta0 = 5.0 * jnp.exp(-(X**2 + Y**2) / 18.0)
    h = jnp.maximum(-b + eta0, 0.0)
    st = SWEState(h, jnp.zeros_like(h), jnp.zeros_like(h))
    dt = stable_dt(cfg, 4000.0)
    for _ in range(25):
        st = step(st, b, cfg, dt)
    assert np.allclose(np.asarray(st.h), np.asarray(st.h)[::-1, :], rtol=1e-5, atol=1e-4)
    assert np.allclose(np.asarray(st.h), np.asarray(st.h)[:, ::-1], rtol=1e-5, atol=1e-4)


def test_wave_propagates_outward():
    sc = TohokuScenario(nx=48, ny=48, t_end=2 * 3600.0)
    fwd = jax.jit(sc.build_forward())
    obs = np.asarray(fwd(jnp.array([0.0, 0.0])))
    hmax1, t1, hmax2, t2 = obs
    assert hmax1 > 0.02 and hmax2 > 0.02  # both probes see the wave
    assert t2 > t1  # farther probe gets the wave later


def test_observables_respond_to_source_location():
    sc = TohokuScenario(nx=36, ny=36, t_end=2 * 3600.0)
    fwd = jax.jit(sc.build_forward())
    a = np.asarray(fwd(jnp.array([-150.0, 0.0])))
    b = np.asarray(fwd(jnp.array([150.0, 0.0])))
    # closer source (larger x, towards probes) arrives earlier
    assert b[1] < a[1]


def test_desingularized_velocity_dry_cells():
    h = jnp.array([0.0, 1e-6, 1.0])
    hu = jnp.array([0.0, 1e-6, 2.0])
    u = np.asarray(desingularized_velocity(h, hu))
    assert np.isfinite(u).all()
    assert abs(u[0]) < 1e-8
    assert abs(u[2] - 2.0) < 1e-5


def test_forward_gradient_exists():
    """UM-Bridge exposes derivatives (paper §2.1) — forward must be differentiable."""
    sc = TohokuScenario(nx=24, ny=24, t_end=1200.0)
    fwd = sc.build_forward()
    g = jax.grad(lambda th: jnp.sum(fwd(th)))(jnp.array([0.0, 0.0]))
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).max()) > 0.0


def test_dt_override_honored_not_falsy_dropped():
    """Regression: ``cfg.dt_override or stable_dt(...)`` silently ignored a
    0.0 override (falsy); the check is now ``is not None`` with explicit
    validation, so a positive override is honored exactly and a
    non-positive one is rejected instead of masked."""
    sc = TohokuScenario(nx=24, ny=24, t_end=600.0)
    base = sc.cfg
    b = sc.bathymetry()
    probes = sc.probe_indices()

    cfg = SWEConfig(nx=base.nx, ny=base.ny, dx=base.dx, dy=base.dy,
                    t_end=base.t_end, dt_override=0.5)
    solver = make_solver(cfg, b, probes)
    assert solver.dt == 0.5
    assert solver.n_steps == int(np.ceil(cfg.t_end / 0.5))

    for bad in (0.0, -1.0):
        bad_cfg = SWEConfig(nx=base.nx, ny=base.ny, dx=base.dx, dy=base.dy,
                            t_end=base.t_end, dt_override=bad)
        with pytest.raises(ValueError, match="dt_override"):
            make_solver(bad_cfg, b, probes)


def test_coarse_fine_observables_correlate():
    """Levels must approximate each other (MLDA's premise)."""
    coarse = TohokuScenario(nx=24, ny=24, t_end=2 * 3600.0)
    fine = TohokuScenario(nx=48, ny=48, t_end=2 * 3600.0)
    fc = jax.jit(coarse.build_forward())
    ff = jax.jit(fine.build_forward())
    rng = np.random.default_rng(0)
    pts = rng.uniform(-150, 150, size=(5, 2))
    a = np.stack([np.asarray(fc(jnp.asarray(p))) for p in pts])
    b = np.stack([np.asarray(ff(jnp.asarray(p))) for p in pts])
    # arrival times across locations correlate strongly between levels
    r = np.corrcoef(a[:, 1], b[:, 1])[0, 1]
    assert r > 0.9
