"""System-level integration: the whole stack importable + cohesive."""
import importlib

import pytest


@pytest.mark.parametrize(
    "mod",
    [
        "repro.core", "repro.swe", "repro.models", "repro.configs",
        "repro.runtime", "repro.data", "repro.optim", "repro.checkpoint",
        "repro.kernels.matern.ops", "repro.kernels.flash_attention.ops",
        "repro.kernels.swe_flux.ops", "repro.launch.mesh", "repro.launch.hlo_cost",
    ],
)
def test_imports(mod):
    importlib.import_module(mod)


def test_all_archs_registered():
    from repro.configs import ARCHS

    assert len(ARCHS) == 10
    for cfg in ARCHS.values():
        r = cfg.reduced()
        assert r.d_model <= 128 and r.vocab <= 512


def test_shape_grid_covers_40_cells():
    from repro.configs import ARCHS, SHAPES, shape_applicable

    total = len(ARCHS) * len(SHAPES)
    assert total == 40
    runnable = sum(
        shape_applicable(a, s)[0] for a in ARCHS.values() for s in SHAPES.values()
    )
    # long_500k runs only for ssm/hybrid/SWA archs (DESIGN.md §4)
    assert runnable == 33
